"""Paged clustered-KV memory manager: a block-pool allocator for the
tail rings of the clustered KV cache (PagedAttention-style memory
management built on the paper's clustering-as-memory-manager thesis).

The dense engine allocates every slot's exact tail as a contiguous
``(slots, R, H, Dh)`` ring — a finished slot, an empty slot, and a slot
whose ring is mostly *covered* (positions already folded into centroids)
all pay the full ``R``.  The paged engine instead carves the tail into
fixed-size blocks of ``block_size`` positions drawn from a shared
per-shard pool:

  * ring offset ``r`` lives at offset ``r % block_size`` of the block at
    ``block_table[slot, r // block_size]`` — the ring *semantics* (position
    ``p`` at offset ``p % R``) are unchanged, only the storage is
    scattered, so the dense and paged engines stay token-identical;
  * blocks are allocated lazily right before the decode/chunk write that
    first touches them, recycled the moment a request exits, and returned
    mid-stream by compaction: once the coverage frontier ``cov`` passes
    every position a block claims, the block's payload is dead (centroids
    summarize it) and it goes back to the free list;
  * one block table is shared by every layer's pool: all clustered leaves
    of a slot advance in lockstep (same ``t``/``cov``), so a single
    (slot, ring-block) → physical-block mapping serves the whole stack;
  * the pool backs the **ring family only** (core/layer_state.py):
    recurrent-state layers ('M'/'R') carry a fixed-size per-slot state
    with no position-indexed tail — block tables skip them entirely, and
    their bytes are accounted separately (``mapped_blocks`` prices a
    slot's pool footprint; the engine adds recurrent state bytes on top
    for victim selection and swap payloads).

The allocator itself is host-side (the engine loop is host-driven and the
table is pushed to the device as a small int32 array each launch); the
block *payloads* are device-resident pool arrays inside the cache pytree
(``k_tail``/``v_tail`` become ``(n_blocks, block_size, H, Dh)``), sharded
over the data mesh axis exactly like slots (sharding/rules.cache_spec).

Ref counts are kept per block so the prefix-sharing admission path
(runtime/prefix_cache.py) can map one physical block into several slots:
``adopt`` installs an extra table mapping onto a live block and
``retain``/``release`` let the prefix cache hold blocks alive with no
table mapping at all.

**Retire-safety argument, in policy terms**: the pool never decides
*what* is dead — a :class:`repro.core.retention.RetentionPolicy` does.
``free_retired(slot, t, policy)`` frees a block exactly when every
position it claims is retired under the policy: claimed position ``p``
is dead iff ``p < policy.retire_lo(slot, t)`` (frontier mode: absorbed
into centroids; window mode: outside the model's own attention window)
or ``p >= t`` and the policy does not ``keep_unwritten`` (the offset was
never written — quota mode keeps these because admission reserved them).
This is safe for *any* policy with monotone ``retire_lo`` because a ring
offset's claimed position only changes when the offset is written, and
every write re-allocates through ``ensure`` first — so a freed block's
payload can never be read again: the masks (cov / window / qpos) that
gate the decode kernels exclude exactly the retired positions the sweep
freed.  ``free_covered`` survives as the frontier-policy wrapper.

**Copy-on-write rule** (the sharing twin of the retire-safety
argument): a ring write may only land in a block the writing slot
owns *exclusively* (``ref == 1``).  ``ensure`` — which every engine-side
ring write goes through first — enforces it: when the write's target
block has ``ref > 1``, a fresh block is allocated from the slot's shard,
the slot's table entry is swapped to it, the shared block's ref is
dropped, and the (src, dst) pair is returned so the engine copies the
payload on device *before* the write executes.  Together with
``free_covered``'s invariant (a ring offset's claimed position only
changes when written, and every write re-allocates through ``ensure``
first), this means a shared block's payload is immutable for as long as
anyone else holds a reference — readers of a shared prefix can never
observe another slot's divergent suffix.

The ref counts back the allocator invariants pinned in
tests/test_properties.py: no double allocation, alloc/free conservation,
live block tables only, no free-list entry with ``ref > 0``, and
COW never mutating a block someone else still references.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Engine-facing paged-KV knobs (ServerConfig.paged).

    ``block_size`` positions per block (must divide the clustered tail
    ``keep_recent``); ``pool_blocks`` blocks per data shard shared by all
    of that shard's slots — 0 = full provisioning (``slots_per_shard *
    keep_recent / block_size``, never exhausts); sizing below that
    oversubscribes memory and relies on admission laziness + compaction
    returning covered blocks (PoolExhausted if a burst outruns it)."""
    block_size: int = 16
    pool_blocks: int = 0


class PoolExhausted(RuntimeError):
    """The per-shard free list ran dry.  Raise rather than silently
    spilling: the caller chose the oversubscription ratio."""


def ring_claims(t: int, r: int) -> np.ndarray:
    """Host mirror of kv_compress.ring_positions: the absolute position
    each of the ``r`` ring offsets claims at watermark ``t`` (next write
    goes to offset ``t % r``)."""
    s = np.arange(r)
    if t <= r:
        return s
    return t - r + np.mod(s - t, r)


def live_blocks(t: int, cov: int, r: int, block_size: int) -> List[int]:
    """Ring-block indices holding at least one live position (claimed
    position in ``[cov, t)``) at watermark ``t``."""
    claims = ring_claims(t, r)
    live = (claims >= cov) & (claims < t)
    return sorted(set((np.nonzero(live)[0] // block_size).tolist()))


def write_blocks(start: int, count: int, r: int, block_size: int) -> List[int]:
    """Ring-block indices touched by writing positions
    ``start .. start+count-1`` (a decode token or a prompt chunk)."""
    offs = np.mod(start + np.arange(count), r)
    return sorted(set((offs // block_size).tolist()))


class _InlineFrontier:
    """Minimal frontier-policy view for ``free_covered`` (duck-typed so
    the pool never imports core.retention)."""

    keep_unwritten = False

    def __init__(self, cov: int, exclude: Sequence[int] = ()):
        self._cov = int(cov)
        self._excl = frozenset(int(b) for b in exclude)

    def retire_lo(self, slot: int, t: int) -> int:
        return self._cov

    def protected_blocks(self, slot: int):
        return self._excl


class BlockPool:
    """Free-list block allocator with per-slot block tables.

    Physical block ids are *global* (``shard * pool_blocks + local``) and
    NamedSharding partitions the pool array's leading axis contiguously,
    so shard ``s`` owns exactly the ids ``[s*pool_blocks, (s+1)*pool_blocks)``
    — a slot only ever references blocks of its own shard, which is what
    lets the Pallas kernel run per mesh shard without collectives.
    """

    def __init__(self, n_slots: int, tail: int, cfg: PagedKVConfig,
                 n_shards: int = 1, slots_per_shard: Optional[int] = None,
                 full_tail_resident: bool = True):
        if tail % cfg.block_size != 0:
            raise ValueError(
                f"block_size {cfg.block_size} must divide the clustered "
                f"tail keep_recent={tail}")
        self.cfg = cfg
        self.tail = tail
        self.block_size = cfg.block_size
        self.blocks_per_slot = tail // cfg.block_size      # T
        self.n_slots = n_slots
        self.n_shards = max(n_shards, 1)
        self.slots_per_shard = (slots_per_shard
                                or max(n_slots // self.n_shards, 1))
        self.pool_blocks = (cfg.pool_blocks or
                            self.slots_per_shard * self.blocks_per_slot)
        # under FrontierRetention a slot at depth >= tail keeps its whole
        # ring mapped, so a pool that can't hold one ring is dead on
        # arrival; under QuotaRetention residency is only the admitted
        # budget (<= blocks_per_slot), so a smaller pool still serves and
        # an unservable request surfaces via the zero-progress backstop
        if full_tail_resident and self.pool_blocks < self.blocks_per_slot:
            raise ValueError(
                f"pool_blocks {self.pool_blocks} cannot hold even one "
                f"slot's tail ({self.blocks_per_slot} blocks)")
        self.n_blocks = self.n_shards * self.pool_blocks
        # -1 = unmapped; otherwise a global physical block id
        self.table = np.full((n_slots, self.blocks_per_slot), -1, np.int32)
        self.ref = np.zeros(self.n_blocks, np.int32)
        # per-block generation, bumped when a block returns to the free
        # list.  A swap record that remembers (gid, gen) can prove at
        # resume time that the block was never recycled in between — and
        # since a live shared block's payload is immutable under the COW
        # rule, an unchanged generation means the device bytes still
        # match the host copy and the re-upload can be skipped entirely
        # (scheduler re-adoption fast path).
        self.gen = np.zeros(self.n_blocks, np.int64)
        # min-heaps per shard (lowest free id first, O(log n) alloc/free)
        self._free: List[List[int]] = [
            list(range(s * self.pool_blocks, (s + 1) * self.pool_blocks))
            for s in range(self.n_shards)
        ]
        self._live = 0
        self._live_shard = np.zeros(self.n_shards, np.int64)
        self.peak_blocks = 0
        self.peak_blocks_shard = np.zeros(self.n_shards, np.int64)
        self.n_allocs = 0
        self.n_frees = 0
        self.n_retains = 0         # extra refs taken (adopt/retain)
        self.n_cow = 0             # copy-on-write block swaps
        # set on every table mutation; the engine caches the device copy
        # of the table and only re-uploads when this flips
        self.dirty = True

    # ------------------------------------------------------------------
    # shard bookkeeping
    # ------------------------------------------------------------------

    def shard_of(self, slot: int) -> int:
        return min(slot // self.slots_per_shard, self.n_shards - 1)

    def shard_base(self, slot: int) -> int:
        return self.shard_of(slot) * self.pool_blocks

    # ------------------------------------------------------------------
    # alloc / free
    # ------------------------------------------------------------------

    def allocated(self) -> int:
        """Physical live blocks — blocks mapped by several slots (or
        pinned by the prefix cache) count ONCE (occupancy and peak-KV
        stats must not double-count shared blocks)."""
        return self._live

    def free_blocks(self, shard: int) -> int:
        """Free-list depth for one data shard — how many fresh blocks
        ``alloc`` can hand out there before ``PoolExhausted``."""
        return len(self._free[shard])

    def mapped_blocks(self, slot: int) -> int:
        """Blocks slot ``slot`` currently maps.  This is the slot's
        ENTIRE pool footprint: the pool backs ring-family tail KV only
        (core/layer_state.py) — recurrent-state layers carry fixed-size
        per-slot state outside the pool, priced separately by the
        engine's victim/swap accounting."""
        return int((self.table[slot] >= 0).sum())

    def shared_extra(self) -> int:
        """Logical table mappings beyond one per physical block — the
        blocks-worth of tail KV that prefix sharing avoided
        materializing at this instant."""
        vals = self.table[self.table >= 0]
        return int(vals.size - np.unique(vals).size)

    def reset_peaks(self) -> None:
        """Start a fresh peak-tracking window from the current live
        occupancy — a pool persisting across serves (template store)
        reports per-serve peaks, not a lifetime high-water mark."""
        self.peak_blocks = self._live
        self.peak_blocks_shard = self._live_shard.copy()

    def _fresh(self, slot: int) -> int:
        """Pop a free block of the slot's shard.  Lowest free id first
        (deterministic)."""
        s = self.shard_of(slot)
        if not self._free[s]:
            raise PoolExhausted(
                f"KV block pool exhausted on data shard {s}: "
                f"{self.pool_blocks} blocks all live — raise "
                f"pool_blocks or shorten refresh_every so compaction "
                f"returns covered blocks sooner")
        gid = heapq.heappop(self._free[s])
        self.ref[gid] = 1
        self.n_allocs += 1
        self._live += 1
        self._live_shard[s] += 1
        self.peak_blocks = max(self.peak_blocks, self._live)
        self.peak_blocks_shard[s] = max(self.peak_blocks_shard[s],
                                        self._live_shard[s])
        return gid

    def alloc(self, slot: int, block_idx: int) -> int:
        """Map (slot, ring-block ``block_idx``) to a fresh physical block
        from the slot's shard; existing mappings are returned as is."""
        if self.table[slot, block_idx] >= 0:
            return int(self.table[slot, block_idx])
        gid = self._fresh(slot)
        self.table[slot, block_idx] = gid
        self.dirty = True
        return gid

    def ensure(self, slot: int, block_indices: Sequence[int],
               pairs: Optional[List[Tuple[int, int]]] = None,
               ) -> List[Tuple[int, int]]:
        """Make every listed ring block writable by ``slot``: unmapped
        blocks get a fresh allocation, and mapped blocks with ``ref > 1``
        are COPY-ON-WRITE swapped — a fresh block replaces the shared one
        in this slot's table and the shared ref is dropped.  The
        (src_gid, dst_gid) pairs the caller must copy on device BEFORE
        the write that prompted the ensure are appended to ``pairs`` (and
        returned).  Raises PoolExhausted mid-list without rolling back
        earlier allocations or COW swaps — pass a caller-owned ``pairs``
        list when a retry/stall path catches the exception, because a
        swap already performed will NOT re-emit its pair on retry (the
        fresh block is exclusively owned by then) and dropping it would
        skip the payload copy and leave the new block uninitialized."""
        if pairs is None:
            pairs = []
        for bi in block_indices:
            gid = int(self.table[slot, bi])
            if gid < 0:
                self.alloc(slot, bi)
            elif self.ref[gid] > 1:
                nid = self._fresh(slot)
                self.table[slot, bi] = nid
                self.dirty = True
                self.n_cow += 1
                self._release(gid)
                pairs.append((gid, nid))
        return pairs

    def retain(self, gid: int) -> None:
        """Take an extra reference on a live block (prefix sharing: the
        prefix cache pins registered blocks, tables aside)."""
        if self.ref[gid] <= 0:
            raise ValueError(f"retain of dead block {gid} (ref "
                             f"{int(self.ref[gid])})")
        self.ref[gid] += 1
        self.n_retains += 1

    def release(self, gid: int) -> None:
        """Drop a reference taken with ``retain``.  Releasing a dead
        block raises cleanly BEFORE any mutation — the count never
        underflows and the free list can never see a double insert."""
        self._release(gid)

    def adopt(self, slot: int, block_idx: int, gid: int) -> None:
        """Map an (unmapped) ring block of ``slot`` onto a live shared
        block — the prefix-sharing admission fast path.  The block must
        belong to the slot's shard (the kernel gathers shard-locally)."""
        if self.table[slot, block_idx] >= 0:
            raise ValueError(
                f"slot {slot} ring block {block_idx} already mapped")
        if gid // self.pool_blocks != self.shard_of(slot):
            raise ValueError(f"block {gid} is not on slot {slot}'s shard")
        self.retain(gid)
        self.table[slot, block_idx] = gid
        self.dirty = True

    def _release(self, gid: int) -> None:
        if self.ref[gid] <= 0:
            raise ValueError(
                f"release of dead block {gid} (ref {int(self.ref[gid])}): "
                "double free — the count is left untouched")
        self.ref[gid] -= 1
        if self.ref[gid] == 0:
            s = gid // self.pool_blocks
            heapq.heappush(self._free[s], int(gid))
            self.gen[gid] += 1
            self.n_frees += 1
            self._live -= 1
            self._live_shard[s] -= 1

    def free_block(self, slot: int, block_idx: int) -> None:
        gid = int(self.table[slot, block_idx])
        if gid < 0:
            return
        self.table[slot, block_idx] = -1
        self.dirty = True
        self._release(gid)

    def free_slot(self, slot: int) -> None:
        """Recycle every block a slot holds (request exit / slot reset)."""
        for bi in range(self.blocks_per_slot):
            self.free_block(slot, bi)

    # ------------------------------------------------------------------
    # preemption swap support (runtime/scheduler.py)
    # ------------------------------------------------------------------

    def release_slot(self, slot: int) -> Dict[int, Tuple[int, int]]:
        """Bulk-release a preempted slot's table, returning
        ``{ring_block_idx: (gid, gen_at_release)}`` for every mapping it
        held.  Shared blocks (prefix-cache pins, other adopters) stay
        live with one fewer ref; exclusively-owned blocks return to the
        free list.  The (gid, gen) pairs are what :meth:`readopt` checks
        at resume time to decide whether the device payload is provably
        unchanged."""
        held: Dict[int, Tuple[int, int]] = {}
        for bi in range(self.blocks_per_slot):
            gid = int(self.table[slot, bi])
            if gid < 0:
                continue
            held[bi] = (gid, int(self.gen[gid]))
            self.free_block(slot, bi)
        return held

    def readopt(self, slot: int, block_idx: int, gid: int,
                gen: int) -> bool:
        """Re-map a resuming slot's ring block onto the physical block it
        held before preemption — but only when the block is provably
        unchanged: still live (someone else kept it referenced the whole
        time, so COW immutability applied throughout), same generation
        (never recycled through the free list), on the resuming slot's
        shard, and the target table entry unmapped.  Returns True on the
        fast path (caller skips the host→device payload upload); False
        means the caller must alloc fresh and re-upload."""
        if self.table[slot, block_idx] >= 0:
            return False
        if not (0 <= gid < self.n_blocks):
            return False
        if self.ref[gid] <= 0 or int(self.gen[gid]) != int(gen):
            return False
        if gid // self.pool_blocks != self.shard_of(slot):
            return False
        self.retain(gid)
        self.table[slot, block_idx] = gid
        self.dirty = True
        return True

    def resume_demand(self, slot: int, held: Dict[int, Tuple[int, int]]) -> int:
        """How many FRESH blocks resuming ``slot`` from ``held``
        (``{ring_block_idx: (gid, gen)}``, a :meth:`release_slot` result)
        would actually pull from the free list: held blocks that would
        survive :meth:`readopt`'s (gid, gen) fast-path checks cost
        nothing.  Read-only — the headroom gate calls this BEFORE
        committing to the resume, so it must not touch any state."""
        s = self.shard_of(slot)
        fresh = 0
        for gid, gen in held.values():
            if (0 <= gid < self.n_blocks and self.ref[gid] > 0
                    and int(self.gen[gid]) == int(gen)
                    and gid // self.pool_blocks == s):
                continue
            fresh += 1
        return fresh

    def publish(self, reg, mark: Tuple[int, int, int, int] = (0, 0, 0, 0),
                bytes_per_block: float = 0.0) -> None:
        """Publish pool metrics into a telemetry registry (duck-typed —
        anything with ``counter``/``gauge`` get-or-create methods).
        ``mark`` is the serve-start snapshot of (n_allocs, n_frees,
        n_retains, n_cow) so per-serve deltas don't double-count."""
        reg.gauge("kv_bytes_peak_per_shard",
                  "peak live tail-KV bytes on the busiest data shard"
                  ).set(float(self.peak_blocks_shard.max()) * bytes_per_block)
        reg.gauge("pool_blocks_total",
                  "pool capacity: blocks per shard x shards"
                  ).set(float(self.n_blocks))
        reg.gauge("pool_blocks_peak",
                  "peak live blocks across the pool this serve"
                  ).set(float(self.peak_blocks))
        reg.gauge("pool_occupancy_peak",
                  "peak live blocks / capacity this serve"
                  ).set(float(self.peak_blocks) / max(self.n_blocks, 1))
        a0, f0, r0, c0 = mark
        reg.counter("pool_allocs", "fresh block allocations this serve"
                    ).add(self.n_allocs - a0)
        reg.counter("pool_frees", "blocks returned to the free list this serve"
                    ).add(self.n_frees - f0)
        reg.counter("pool_retains", "extra refs taken (adopt/retain) this serve"
                    ).add(self.n_retains - r0)
        reg.counter("pool_cow", "copy-on-write block swaps this serve"
                    ).add(self.n_cow - c0)

    def free_retired(self, slot: int, t: int, policy) -> int:
        """Return blocks whose every claimed position is retired under
        ``policy`` (see the module docstring's retire-safety argument).

        A claimed position ``p`` is dead iff ``p < policy.retire_lo(slot,
        t)``, or ``p >= t`` (allocated-but-unwritten) when the policy
        does not ``keep_unwritten``.  Ring blocks the policy has
        write-protected (``policy.protect_write`` — an imminent launch
        will scatter into them) are skipped even if dead: freeing one
        would just force ``ensure`` to re-allocate it and the reclaim
        loop to spin."""
        freed = 0
        lo = int(policy.retire_lo(slot, t))
        keep_unwritten = bool(policy.keep_unwritten)
        protected = policy.protected_blocks(slot)
        claims = ring_claims(t, self.tail)
        for bi in range(self.blocks_per_slot):
            if self.table[slot, bi] < 0 or bi in protected:
                continue
            blk = claims[bi * self.block_size:(bi + 1) * self.block_size]
            dead = blk < lo
            if not keep_unwritten:
                dead = dead | (blk >= t)
            if dead.all():
                self.free_block(slot, bi)
                freed += 1
        return freed

    def free_covered(self, slot: int, t: int, cov: int,
                     exclude: Sequence[int] = ()) -> int:
        """Frontier-policy wrapper around ``free_retired``: free blocks
        whose every claimed position is ``< cov`` (absorbed into
        centroids) or not yet written — the compaction give-back, with
        ``exclude`` standing in for write protection."""
        return self.free_retired(slot, t, _InlineFrontier(cov, exclude))

    # ------------------------------------------------------------------
    # device views
    # ------------------------------------------------------------------

    def table_for_read(self) -> np.ndarray:
        """Block table with unmapped entries pointing at the slot's shard
        base block — a valid gather target whose payload is garbage at
        offsets the position/coverage masks already exclude."""
        out = self.table.copy()
        for slot in range(self.n_slots):
            row = out[slot]
            row[row < 0] = self.shard_base(slot)
        return out

    def row_for_read(self, slot: int) -> np.ndarray:
        """One slot's read-sanitized table row (per-slot absorb path —
        avoids copying the whole table for a (T,) gather)."""
        row = self.table[slot].copy()
        row[row < 0] = self.shard_base(slot)
        return row

    def table_for_write(self) -> np.ndarray:
        """Block table with unmapped entries out of range (``n_blocks``)
        so scatters with mode='drop' skip them."""
        out = self.table.copy()
        out[out < 0] = self.n_blocks
        return out

    def row_for_write(self, slot: int) -> np.ndarray:
        """One slot's write-sanitized table row (admission slot-write)."""
        row = self.table[slot].copy()
        row[row < 0] = self.n_blocks
        return row

    # ------------------------------------------------------------------
    # invariant checks (exercised by Hypothesis property tests)
    # ------------------------------------------------------------------

    def check_invariants(self) -> None:
        owners: Dict[int, List[Tuple[int, int]]] = {}
        for slot in range(self.n_slots):
            for bi in range(self.blocks_per_slot):
                gid = int(self.table[slot, bi])
                if gid >= 0:
                    owners.setdefault(gid, []).append((slot, bi))
        for gid, who in owners.items():
            assert self.ref[gid] >= len(who), (
                f"block {gid} mapped {len(who)}x with ref {self.ref[gid]}")
            assert self.ref[gid] > 0, f"table points at dead block {gid}"
            for slot, _bi in who:
                assert gid // self.pool_blocks == self.shard_of(slot), (
                    f"slot {slot} maps block {gid} of another shard")
        assert self._live == int((self.ref > 0).sum()), \
            "live counter drifted from ref counts"
        for s in range(self.n_shards):
            lo, hi = s * self.pool_blocks, (s + 1) * self.pool_blocks
            free = set(self._free[s])
            live = {g for g in range(lo, hi) if self.ref[g] > 0}
            assert not (free & live), "free list overlaps live blocks"
            assert len(free) + len(live) == self.pool_blocks, (
                "alloc/free leak: free + live != pool")
            for g in free:
                assert lo <= g < hi, "free id escaped its shard"
