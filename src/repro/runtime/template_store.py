"""Persistent cross-serve template store with incremental request
clustering — the prefix cache promoted from a per-serve scratch index to
a durable, self-organizing template registry.

`runtime/prefix_cache.py` already makes one serve's templated traffic
cheap: chunk-boundary slot state is prefix-pure, so later same-prefix
admissions adopt registered tail blocks and centroid snapshots instead
of re-prefilling.  But the cache was built inside ``Server.serve`` and
cleared at the end of it, so template knowledge never survived a request
stream.  This module keeps it alive across ``serve()`` calls.

Persistence safety argument
---------------------------
A registered snapshot is reusable across serve calls because every input
that determines it is pinned for the lifetime of the store:

* **The bytes cannot change.**  An entry ``retain``-s its tail-ring pool
  blocks, so the allocator never recycles them, and copy-on-write
  (``kv_pool.ensure``) gives any writer of a ``ref > 1`` block a private
  copy first — a pinned payload is immutable from the moment of
  registration.  The centroid snapshot is an ordinary device array the
  entry owns; no jit donates it.  Between serves nothing writes at all:
  the engine hands the pool and the device cache back to the server
  instance, and the only live references into them are the store's pins.
* **The bytes stay *meaningful*.**  Chunk-boundary state is a
  deterministic function of ``(tokens[:fed], prefill_chunk,
  KVCompressConfig, model params)`` alone (per-slot compaction gating
  keeps neighbours out of it).  The first three are frozen on the
  ``Server``; the store stamps all of them — plus the params' identity
  and the pool it was registered against — into an **epoch** at
  ``bind()``.  A bind with a different epoch (new model, new
  ``KVCompressConfig``, new pool after a crashed serve, a different
  ``Server`` reusing the store object) invalidates every entry before
  any lookup can adopt a stale snapshot.  Token equality is still
  verified on every hit, exactly as within one serve.

What invalidates the store: ``Server.invalidate_templates()`` (explicit),
an epoch change at ``bind()`` (implicit, conservative), and per-entry
eviction under capacity or pool pressure.  Invalidation releases every
pinned block, so the pool drains to zero; short of it the end-of-serve
invariant is ``pool.allocated() == store.pinned_blocks()``.

Eviction: templates must *earn* their pinned blocks.  Under pool
pressure and the per-shard capacity cap the store drops the entry with
the lowest ``hits × tokens-reused`` score (LRU stamp breaks ties), not
the plain-LRU victim: a template boundary that keeps collapsing
admissions is worth more than a recently-registered suffix-contaminated
boundary that nothing ever hits.  Entries mid-adoption are pinned
(``in_flight``) and never evicted — see ``PrefixCache.lookup``.

Incremental request clustering
------------------------------
The store also clusters the live request traffic online, in the style of
Mettu & Plaxton's online-medoid construction and nearest-neighbor
incremental assignment (Yadav et al.):

* each incoming prompt is assigned to the cluster of its **nearest
  registered boundary** — the longest ``(fed, digest)`` candidate that
  matches a registered entry on any shard (digest-prefix
  nearest-neighbor; token equality verified);
* an unmatched prompt is tracked by its shortest boundary digest (its
  *family*); when a family recurs ``promote_after`` times the digest is
  promoted to a cluster **medoid** (Mettu–Plaxton-style: recurring mass
  at a point makes it a center) and subsequent members assign to it;
* the engine steers same-cluster requests onto the data shards already
  holding that cluster's entries (``shard_affinity``), extending the
  ``match_len`` steering so back-to-back template bursts land where
  their blocks live.

Per-cluster cohesion (matched prefix tokens / prompt tokens), hit rate,
and bytes pinned are reported through ``stats()`` / ``cluster_stats()``
into ``last_stats`` and the serve benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.prefix_cache import (PrefixCache, PrefixEntry,
                                        PrefixShareConfig)


@dataclasses.dataclass(frozen=True)
class TemplateStoreConfig:
    """Knobs for the persistent template store
    (``ServerConfig.template_store``).

    ``max_entries``/``min_prefix`` mean what they mean for
    ``PrefixShareConfig`` — but entries now pin pool blocks *between*
    serves too, so ``max_entries`` bounds the standing pinned-memory
    cost of an idle server, not just a transient within one stream.
    ``promote_after`` is the Mettu–Plaxton recurrence threshold: how
    many times an unmatched prompt family must be seen before its
    digest is promoted to a cluster medoid.  ``retire_after`` is the
    recurrence-*decay* twin (0 = never retire): a medoid whose cluster
    saw no member, hit, or registration for that many ``assign()``
    ticks is pruned — a long-lived server sheds dead
    ``TemplateCluster`` records instead of accumulating every template
    family it ever met, the symmetric counterpart of promotion (mass
    arriving makes a center; mass decaying unmakes it).  Unpromoted
    family recurrence counts decay on the same clock."""
    max_entries: int = 32
    min_prefix: int = 0
    promote_after: int = 2
    retire_after: int = 0


@dataclasses.dataclass
class TemplateCluster:
    cid: int
    medoid: bytes             # digest of the medoid prefix boundary
    medoid_fed: int           # boundary length of the medoid, in tokens
    members: int = 0          # requests assigned (lifetime)
    hits: int = 0             # store hits by members
    tokens_reused: int = 0    # prompt tokens members skipped
    prompt_tokens: int = 0    # total prompt tokens over members
    matched_tokens: int = 0   # matched boundary tokens at assignment
    last_seen: int = 0        # assign-tick of last member/hit activity

    @property
    def cohesion(self) -> float:
        """How much of the cluster's prompt mass its shared boundary
        explains (1.0 = members are pure template repeats)."""
        return self.matched_tokens / max(self.prompt_tokens, 1)

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.members, 1)


class TemplateStore(PrefixCache):
    """Cross-serve ``PrefixCache``: same per-shard boundary maps and the
    same engine-facing API, plus epoch-stamped persistence, scored
    eviction, and online traffic clustering.  Construct it unbound; the
    server binds it to its pool (and epoch) at each serve."""

    def __init__(self, cfg: Optional[TemplateStoreConfig] = None):
        self.tcfg = cfg or TemplateStoreConfig()
        super().__init__(PrefixShareConfig(
            max_entries=self.tcfg.max_entries,
            min_prefix=self.tcfg.min_prefix), 1, None)
        self.epoch: object = None
        self.invalidations = 0
        self._clusters: Dict[int, TemplateCluster] = {}
        # digest -> (recurrences, last assign-tick seen)
        self._families: Dict[bytes, Tuple[int, int]] = {}
        self._medoid_cid: Dict[bytes, int] = {}  # promoted digest -> cid
        self._next_cid = 0
        self._tick = 0                 # assign() clock for retirement
        self.clusters_retired = 0
        # canonical warm-handoff slot: (pool, device cache, epoch,
        # n_shards) parked by the server at end-of-serve.  Living on the
        # STORE (not the Server) makes the epoch's content-hashed weight
        # stamp meaningful — a brand-new Server over a reloaded pytree
        # with identical bytes adopts the parked pool + cache and keeps
        # every pin, instead of cold-binding on pool identity.  Adoption
        # clears the slot eagerly (single ownership: an older server
        # serving afterwards simply rebinds cold).
        self.parked: Optional[tuple] = None

    @property
    def share(self) -> PrefixShareConfig:
        """The engine-facing prefix-sharing view of this store."""
        return self.cfg

    # ------------------------------------------------------------------
    # persistence lifecycle
    # ------------------------------------------------------------------

    def bind(self, epoch, n_shards: int, pool) -> bool:
        """Attach the store to a serve's pool under a config epoch.
        Same (epoch, pool, shard count) as the previous bind → warm
        rebind, entries kept.  Anything else → the previous contents are
        invalidated first: a new ``KVCompressConfig``, model params, or
        pool can never adopt a stale snapshot.  Returns True when the
        store came up cold (invalidated or first bind)."""
        if (self.pool is pool and self.epoch == epoch
                and len(self._maps) == n_shards):
            return False
        if self.pool is not None:
            self.invalidate()
        self.epoch = epoch
        self.pool = pool
        self._maps = [{} for _ in range(max(n_shards, 1))]
        return True

    def invalidate(self) -> None:
        """Drop every entry (releasing its pinned blocks against the
        pool it was registered with) and reset the traffic clustering.
        Lifetime hit counters survive — per-serve stats are deltas."""
        for shard in range(len(self._maps)):
            for key in list(self._maps[shard]):
                e = self._maps[shard][key]
                if e.in_flight:
                    raise RuntimeError(
                        "invalidate with an adoption in flight — the "
                        "engine must finish restoring before the store "
                        "can drop the entry under it")
                self._drop(shard, key)
        self._clusters.clear()
        self._families.clear()
        self._medoid_cid.clear()
        self.parked = None
        self.invalidations += 1

    def pinned_blocks(self) -> int:
        """Distinct physical blocks the store keeps alive — the pool's
        end-of-serve drain target: ``pool.allocated() == pinned_blocks()``
        once every request has exited."""
        gids = set()
        for m in self._maps:
            for e in m.values():
                gids.update(e.blocks.values())
        return len(gids)

    # ------------------------------------------------------------------
    # scored eviction (overrides pure LRU)
    # ------------------------------------------------------------------

    def evict_lru(self, shard: int) -> bool:
        """Evict the entry with the lowest hits × tokens-reused score
        (LRU stamp breaks ties among never-hit entries): under pool
        pressure the store keeps the templates that earn their pinned
        blocks.  Entries mid-adoption are skipped.  Keeps the base-class
        name — the engine's reclaim paths call it blindly."""
        m = self._maps[shard]
        cands = [k for k, e in m.items() if e.in_flight == 0]
        if not cands:
            return False
        key = min(cands, key=lambda k: (m[k].hits * m[k].fed, m[k].stamp))
        self._drop(shard, key)
        return True

    # ------------------------------------------------------------------
    # incremental traffic clustering
    # ------------------------------------------------------------------

    def _promote(self, dig: bytes, fed: int) -> int:
        cid = self._medoid_cid.get(dig)
        if cid is None:
            cid = self._next_cid
            self._next_cid += 1
            self._medoid_cid[dig] = cid
            self._clusters[cid] = TemplateCluster(cid=cid, medoid=dig,
                                                  medoid_fed=fed,
                                                  last_seen=self._tick)
            self._families.pop(dig, None)
        return cid

    def _touch(self, cid: int) -> None:
        c = self._clusters.get(cid)
        if c is not None:
            c.last_seen = self._tick

    def _retire(self) -> None:
        """Recurrence-decay pruning (Mettu–Plaxton in reverse): drop
        clusters idle for ``retire_after`` assign ticks and family
        counts just as stale.  Entries of a retired cluster stay valid
        (their snapshots/blocks are cluster-agnostic) but de-associate
        — a later recurrence re-promotes from scratch, exactly like a
        never-seen family."""
        horizon = self._tick - self.tcfg.retire_after
        dead = [cid for cid, c in self._clusters.items()
                if c.last_seen < horizon]
        for cid in dead:
            c = self._clusters.pop(cid)
            self._medoid_cid.pop(c.medoid, None)
            for m in self._maps:
                for e in m.values():
                    if e.cluster == cid:
                        e.cluster = -1
            self.clusters_retired += 1
        for dig in [d for d, (_, seen) in self._families.items()
                    if seen < horizon]:
            del self._families[dig]

    def assign(self, prompt: np.ndarray,
               digests: List[Tuple[int, bytes]]) -> int:
        """Assign one incoming request to a prefix cluster (call once
        per request).  Nearest-neighbor over registered boundaries,
        longest first; unmatched prompts accrue family recurrences until
        medoid promotion.  Returns the cluster id, or -1 while the
        prompt's family is still below the promotion threshold."""
        self._tick += 1
        if self.tcfg.retire_after > 0:
            self._retire()
        plen = len(prompt)
        for fed, dig in digests:
            for m in self._maps:
                e = m.get((fed, dig))
                if e is not None and np.array_equal(e.tokens, prompt[:fed]):
                    if e.cluster < 0:
                        # entry registered before its family recurred:
                        # the recurrence is happening now — promote
                        e.cluster = self._promote(dig, fed)
                    c = self._clusters[e.cluster]
                    c.members += 1
                    c.matched_tokens += fed
                    c.prompt_tokens += plen
                    c.last_seen = self._tick
                    return e.cluster
        if not digests:
            return -1
        fam_fed, fam_dig = digests[-1]   # shortest boundary = family key
        cid = self._medoid_cid.get(fam_dig)
        if cid is None:
            seen = self._families.get(fam_dig, (0, 0))[0] + 1
            self._families[fam_dig] = (seen, self._tick)
            if seen < self.tcfg.promote_after:
                return -1
            cid = self._promote(fam_dig, fam_fed)
        c = self._clusters[cid]
        c.members += 1
        c.prompt_tokens += plen
        c.last_seen = self._tick
        return cid

    def shard_affinity(self, shard: int, cid: int) -> int:
        """Entries of cluster ``cid`` living on ``shard`` — the steering
        signal that sends same-cluster requests back-to-back onto the
        shards already holding their blocks."""
        if cid < 0:
            return 0
        return sum(1 for e in self._maps[shard].values()
                   if e.cluster == cid)

    def lookup(self, shard: int, prompt: np.ndarray, chunk: int,
               digests: Optional[List[Tuple[int, bytes]]] = None,
               ) -> Optional[PrefixEntry]:
        e = super().lookup(shard, prompt, chunk, digests=digests)
        if e is not None and e.cluster >= 0:
            c = self._clusters.get(e.cluster)
            if c is not None:
                c.hits += 1
                c.tokens_reused += e.fed
                c.last_seen = self._tick
        return e

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def cluster_stats(self) -> List[Dict[str, float]]:
        """Per-cluster records (largest membership first)."""
        out = []
        for c in sorted(self._clusters.values(),
                        key=lambda c: (-c.members, c.cid)):
            gids = set()
            for m in self._maps:
                for e in m.values():
                    if e.cluster == c.cid:
                        gids.update(e.blocks.values())
            out.append({"cid": float(c.cid), "members": float(c.members),
                        "hits": float(c.hits),
                        "hit_rate": float(c.hit_rate),
                        "tokens_reused": float(c.tokens_reused),
                        "cohesion": float(c.cohesion),
                        "blocks_pinned": float(len(gids))})
        return out

    def publish(self, reg, bytes_per_block: float = 0.0,
                max_clusters: int = 8) -> None:
        """Publish store metrics into a telemetry registry (duck-typed).

        Key names match :meth:`stats` plus the per-cluster
        ``template_cluster{cid}_*`` trio for the ``max_clusters`` largest
        clusters.  Lifetime ``*_total`` counters register with
        ``persist=True`` (the store outlives serves) and republish via
        ``set_to``; everything else is a per-serve gauge, so stale
        cluster keys from a previous serve can never leak."""
        st = self.stats()
        reg.gauge("template_entries",
                  "prefix entries registered in the store"
                  ).set(st["template_entries"])
        reg.gauge("template_pinned_blocks",
                  "pool blocks pinned by store entries"
                  ).set(st["template_pinned_blocks"])
        reg.counter("template_hits_total",
                    "lifetime prefix-adoption hits", persist=True
                    ).set_to(st["template_hits_total"])
        reg.counter("template_tokens_reused_total",
                    "lifetime prompt tokens adopted from the store",
                    persist=True).set_to(st["template_tokens_reused_total"])
        reg.gauge("template_clusters",
                  "live traffic clusters").set(st["template_clusters"])
        reg.counter("template_clusters_retired",
                    "clusters retired under recurrence decay", persist=True
                    ).set_to(st["template_clusters_retired"])
        reg.gauge("template_cohesion_mean",
                  "mean matched/prompt token cohesion over live clusters"
                  ).set(st["template_cohesion_mean"])
        reg.gauge("template_bytes_pinned",
                  "bytes of tail KV pinned by store entries"
                  ).set(st["template_pinned_blocks"] * bytes_per_block)
        for c in self.cluster_stats()[:max_clusters]:
            cid = int(c["cid"])
            reg.gauge(f"template_cluster{cid}_cohesion",
                      f"cluster {cid}: matched/prompt cohesion"
                      ).set(c["cohesion"])
            reg.gauge(f"template_cluster{cid}_hit_rate",
                      f"cluster {cid}: hits per member admission"
                      ).set(c["hit_rate"])
            reg.gauge(f"template_cluster{cid}_bytes_pinned",
                      f"cluster {cid}: bytes pinned by its entries"
                      ).set(c["blocks_pinned"] * bytes_per_block)

    def stats(self) -> Dict[str, float]:
        live = [c for c in self._clusters.values() if c.members]
        coh = [c.cohesion for c in live]
        return {
            "template_entries": float(sum(len(m) for m in self._maps)),
            "template_pinned_blocks": float(self.pinned_blocks()),
            "template_hits_total": float(self.hits),
            "template_tokens_reused_total": float(self.tokens_reused),
            "template_clusters": float(len(live)),
            "template_clusters_retired": float(self.clusters_retired),
            "template_cohesion_mean": (float(np.mean(coh)) if coh
                                       else 0.0),
        }
